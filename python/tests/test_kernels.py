"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Sweeps shapes/seeds (hypothesis-style grid; the hypothesis package is not
assumed installed on this image) and checks forward values and every
gradient the training path uses.
"""

import jax
import jax.numpy as jnp
import pytest

from compile.kernels import (capacity_loss, decode_attention,
                             retention_attention, retention_load)
from compile.kernels.ref import (capacity_loss_ref, decode_attention_ref,
                                 retention_attention_ref,
                                 retention_matrix_ref)

SHAPES = [
    # (B, Hq, Hkv, T, dh)
    (1, 2, 1, 32, 8),
    (2, 4, 2, 64, 16),
    (1, 4, 4, 128, 32),   # MHA (group = 1)
    (2, 8, 2, 96, 16),    # wide GQA group
]


def _inputs(b, hq, hkv, t, dh, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, hq, t, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, t, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, t, dh), jnp.float32)
    lb = -jax.nn.softplus(jax.random.normal(ks[3], (b, hkv, t)))
    return q, k, v, lb


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("seed", [0, 1])
def test_retention_attention_fwd(shape, seed):
    q, k, v, lb = _inputs(*shape, seed)
    out = retention_attention(q, k, v, lb)
    ref = retention_attention_ref(q, k, v, lb)
    assert jnp.abs(out - ref).max() < 2e-5


@pytest.mark.parametrize("block", [16, 32, 128])
def test_retention_attention_block_sizes(block):
    q, k, v, lb = _inputs(1, 2, 1, 64, 8, 3)
    out = retention_attention(q, k, v, lb, block, block)
    ref = retention_attention_ref(q, k, v, lb)
    assert jnp.abs(out - ref).max() < 2e-5


@pytest.mark.parametrize("shape", SHAPES[:2])
def test_retention_attention_grads(shape):
    q, k, v, lb = _inputs(*shape, 5)

    def loss_k(f):
        return (retention_attention(q, k, v, lb) * f).sum()

    def loss_r(f):
        return (retention_attention_ref(q, k, v, lb) * f).sum()

    f = jax.random.normal(jax.random.PRNGKey(9), q.shape)
    for argfn, name in [
        (lambda fn: jax.grad(lambda q_: (fn(q_, k, v, lb) * f).sum())(q), "dq"),
        (lambda fn: jax.grad(lambda k_: (fn(q, k_, v, lb) * f).sum())(k), "dk"),
        (lambda fn: jax.grad(lambda v_: (fn(q, k, v_, lb) * f).sum())(v), "dv"),
        (lambda fn: jax.grad(lambda lb_: (fn(q, k, v, lb_) * f).sum())(lb), "dlb"),
    ]:
        gk = argfn(retention_attention)
        gr = argfn(retention_attention_ref)
        scale = jnp.abs(gr).max() + 1e-6
        assert jnp.abs(gk - gr).max() / scale < 5e-4, name


def test_retention_attention_all_beta_one_is_vanilla():
    """beta == 1 must recover standard causal attention (paper §4.1)."""
    q, k, v, _ = _inputs(1, 2, 2, 32, 8, 11)
    lb = jnp.zeros((1, 2, 32))
    out = retention_attention(q, k, v, lb)
    ref = retention_attention_ref(q, k, v, lb)
    # vanilla softmax attention computed directly
    s = jnp.einsum("bhtd,bhid->bhti", q, k) / jnp.sqrt(8.0)
    mask = jnp.tril(jnp.ones((32, 32), bool))
    s = jnp.where(mask, s, -1e30)
    van = jnp.einsum("bhti,bhid->bhtd", jax.nn.softmax(s, -1), v)
    assert jnp.abs(out - van).max() < 2e-5
    assert jnp.abs(ref - van).max() < 2e-5


@pytest.mark.parametrize("m", [1.0, 4.0, 16.0])
@pytest.mark.parametrize("seed", [0, 2])
def test_capacity_loss(m, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 1)[0]
    lb = -jax.nn.softplus(jax.random.normal(ks, (2, 3, 96)))
    a = capacity_loss(lb, m)
    b = capacity_loss_ref(lb, m)
    assert abs(float(a) - float(b)) < 1e-5
    ga = jax.grad(lambda x: capacity_loss(x, m))(lb)
    gb = jax.grad(lambda x: capacity_loss_ref(x, m))(lb)
    assert jnp.abs(ga - gb).max() < 1e-6


def test_capacity_loss_zero_when_under_budget():
    lb = jnp.full((1, 1, 64), -3.0)  # beta ~ 0.05: load stays tiny
    assert float(capacity_loss(lb, 8.0)) == 0.0


def test_retention_load_matches_matrix_sum():
    lb = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(4), (1, 2, 64)))
    s = retention_load(lb)
    mat = retention_matrix_ref(lb)
    assert jnp.abs(s - mat.sum(-1)).max() < 2e-4


@pytest.mark.parametrize("m", [16, 64])
@pytest.mark.parametrize("frac", [0.0, 0.4, 1.0])
def test_decode_attention(m, frac):
    b, hq, hkv, dh = 2, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (b, hq, dh))
    k = jax.random.normal(ks[1], (b, hkv, m, dh))
    v = jax.random.normal(ks[2], (b, hkv, m, dh))
    valid = (jax.random.uniform(ks[3], (b, hkv, m)) >= frac).astype(jnp.float32)
    o1, p1 = decode_attention(q, k, v, valid)
    o2, p2 = decode_attention_ref(q, k, v, valid)
    assert jnp.abs(o1 - o2).max() < 2e-5
    assert jnp.abs(p1 - p2).max() < 2e-6
    # probabilities are a distribution over live slots
    live = valid.sum() > 0
    if frac == 0.0:
        assert jnp.abs(p1.sum(-1) - 1.0).max() < 1e-4


def test_decode_attention_all_invalid_is_zero():
    b, hq, hkv, m, dh = 1, 2, 1, 8, 4
    q = jnp.ones((b, hq, dh))
    k = jnp.ones((b, hkv, m, dh))
    v = jnp.ones((b, hkv, m, dh))
    valid = jnp.zeros((b, hkv, m))
    o, p = decode_attention(q, k, v, valid)
    assert jnp.abs(o).max() == 0.0
    assert jnp.abs(p).max() == 0.0
