"""Staticcheck analyzer tests: each rule trips on its seeded-violation
fixture, stays silent where the fixture is deliberately clean, and the
whole suite reports zero findings on the real tree (the CI gate this repo
actually ships under).

No jax needed — pure python over ``tools/staticcheck``.
"""

import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent.parent
sys.path.insert(0, str(REPO / "tools"))

from staticcheck import rustlex  # noqa: E402
from staticcheck.run import analyze  # noqa: E402

FIXTURES = REPO / "tools" / "staticcheck" / "fixtures"


def findings_for(fixture, rule):
    return analyze(FIXTURES / fixture, only=rule)


def messages(findings):
    return "\n".join(f.render() for f in findings)


# -- the lexer itself -------------------------------------------------------

def test_scrub_blanks_comments_and_strings():
    s = rustlex.scrub(
        'let a = "x.unwrap()"; // .unwrap() in a comment\n'
        "let b = v.unwrap();\n", "t.rs")
    assert ".unwrap()" not in s.code.split("\n")[0]
    assert "v.unwrap()" in s.code
    assert len(s.code) == len(s.text)  # offsets preserved
    assert s.strings == [(1, "x.unwrap()")]


def test_scrub_line_of_is_exact_at_boundaries():
    s = rustlex.scrub("a\nbb\nccc\n", "t.rs")
    for pos, want in [(0, 1), (1, 1), (2, 2), (4, 2), (5, 3), (8, 3)]:
        assert s.line_of(pos) == want, (pos, want)


def test_scrub_marks_cfg_test_extent():
    s = rustlex.scrub(
        "fn live() {}\n"
        "#[cfg(test)]\n"
        "mod tests {\n"
        "    fn t() {}\n"
        "}\n"
        "fn after() {}\n", "t.rs")
    assert not s.in_test(1)
    assert s.in_test(4)
    assert not s.in_test(6)


def test_pragma_parsing():
    s = rustlex.scrub(
        "// staticcheck: allow(panic-path, index proven in range)\n"
        "// staticcheck: allow(lock-order)\n", "t.rs")
    assert [(p.line, p.rule, p.reason) for p in s.pragmas] == [
        (1, "panic-path", "index proven in range"),
        (2, "lock-order", "")]


# -- each rule trips on its fixture ----------------------------------------

def test_metrics_registry_fixture():
    f = findings_for("metrics_registry", "metrics-registry")
    msgs = messages(f)
    assert len(f) == 4, msgs
    assert "trimkv_orphan_total` is emitted but not documented" in msgs
    assert "trimkv_ghost_total` is documented but nothing" in msgs
    # the rename pair is flagged in both directions with a near-miss hint
    assert "near-miss of documented `trimkv_prefix_bytes_total`" in msgs
    assert "near-miss of emitted `trimkv_prefix_byte_total`" in msgs
    # silent: the clean series, and names inside #[cfg(test)]
    assert "trimkv_requests_total" not in msgs
    assert "trimkv_test_only_total" not in msgs


def test_config_contract_fixture():
    f = findings_for("config_contract", "config-contract")
    msgs = messages(f)
    assert len(f) == 6, msgs
    assert "`gamma` is not settable via TOML" in msgs
    assert "`engine.gamma` has no from_toml_str arm" in msgs
    assert "--omega but apply_cli never consumes it" in msgs
    assert "--omega default `\"42\".to_string()` is not derived" in msgs
    assert "documents default `0.7` but EngineConfig::default() says `0.5`" \
        in msgs
    assert "`engine.beta` (field `beta`) is missing from" in msgs
    # silent: alpha is fully wired (arm + CLI + docs row)
    assert "--alpha" not in msgs


def test_lock_order_fixture():
    f = findings_for("lock_order", "lock-order")
    msgs = messages(f)
    assert len(f) == 4, msgs
    assert "`alpha` acquired while holding `beta`" in msgs
    assert "`alpha` re-acquired while already held" in msgs
    assert "blocking call `.recv(` while holding `alpha`" in msgs
    assert "undeclared lock `secret.lock()`" in msgs
    # silent: the declared alpha -> beta nesting, the drop-before-recv
    # function, and nesting inside #[cfg(test)]
    assert "`beta` acquired while holding `alpha`" not in msgs
    lines = {x.line for x in f}
    assert all(line < 45 for line in lines), msgs  # nothing from mod tests


def test_panic_path_fixture():
    f = findings_for("panic_path", "panic-path")
    msgs = messages(f)
    assert len(f) == 5, msgs
    assert "`unwrap` on a serving hot path" in msgs
    assert "2 non-test panic sites but the baseline allows 1" in msgs
    assert "baseline is stale: allows 2 panic sites, the file has 1" in msgs
    assert "allow(panic-path) carries no reason" in msgs
    assert "unused allow(panic-path) pragma" in msgs
    # silent: the reasoned pragma'd expect, and unwraps in #[cfg(test)]
    assert msgs.count("serving hot path") == 1


def test_bench_gates_fixture():
    f = findings_for("bench_gates", "bench-gates")
    msgs = messages(f)
    assert len(f) == 3, msgs
    assert "gates `fake_b` but BENCH_baseline.json has no" in msgs
    assert "baseline gates `fake.fake_stale` but the bench no longer" in msgs
    assert 'baseline entry `ghost` has no bench' in msgs
    assert "fake_a" not in msgs  # silent: the covered gate


def test_doc_links_fixture():
    f = findings_for("doc_links", "doc-links")
    msgs = messages(f)
    assert len(f) == 1, msgs
    assert f[0].path == "README.md"
    assert "missing/file.md" in msgs
    # silent: live links, anchors, external URLs, fenced snippets, fragments
    assert "OTHER.md" not in msgs and "nowhere.md" not in msgs


# -- the real tree is clean -------------------------------------------------

@pytest.mark.parametrize("rule", ["metrics-registry", "config-contract",
                                  "lock-order", "panic-path", "bench-gates",
                                  "doc-links"])
def test_real_tree_is_clean_per_rule(rule):
    f = analyze(REPO, only=rule)
    assert f == [], messages(f)


def test_real_tree_is_clean_full_suite():
    f = analyze(REPO)
    assert f == [], messages(f)
