//! Long-memory chat scenario (LongMemEval analog, paper §5.2), served as
//! TRUE multi-turn dialogues through the session subsystem: each dialogue
//! streams turn-by-turn under one session id, its KV cache surviving
//! between turns (parked on a lane, or swapped through the host
//! `SessionStore` when more dialogues than lanes compete).  Prior turns are
//! NEVER re-prefilled — compare against the flattened-prompt baseline that
//! re-feeds the whole history every dialogue.
//!
//!   make artifacts && cargo run --release --example longmem_chat
//!
//! Without artifacts the demo runs on the deterministic MockBackend and
//! asserts token-level equivalence between session-served and flattened
//! dialogues (the swap-identity property, end to end).

use anyhow::{Context, Result};
use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::model_meta::ModelMeta;
use trimkv::runtime::{MockBackend, ModelBackend, PjrtBackend};
use trimkv::scheduler::Request;
use trimkv::vocab::Vocab;
use trimkv::workload::{grade, suites};

/// Split a multi-session episode prompt into dialogue turns at each
/// `<session>` marker; the trailing `<sep> <query> k` tail is its own turn.
/// Concatenating the turns reproduces the flat prompt exactly.
fn split_turns(prompt: &[u32], v: &Vocab) -> Vec<Vec<u32>> {
    let mut turns: Vec<Vec<u32>> = Vec::new();
    let mut cur: Vec<u32> = Vec::new();
    for &t in prompt {
        let boundary = t == v.session() || t == v.sep();
        if boundary && cur.len() > 1 {
            turns.push(std::mem::take(&mut cur));
        }
        cur.push(t);
    }
    if !cur.is_empty() {
        turns.push(cur);
    }
    turns
}

struct ModeStats {
    accuracy: f64,
    final_tokens: Vec<Vec<u32>>,
    tokens_prefilled: u64,
    session_summary: String,
    /// per dialogue, per intermediate turn: the assistant's sampled reply
    inter_replies: Vec<Vec<Vec<u32>>>,
}

/// Serve every dialogue turn-by-turn through sessions; all dialogues at
/// turn j run concurrently over the engine's lanes, so sessions park,
/// preempt and swap exactly as a live chat fleet would.
fn run_session_mode<B: ModelBackend>(
    backend: B, vocab: &Vocab, policy: &str, budget: usize, batch: usize,
    turnlists: &[Vec<Vec<u32>>], answers: &[&trimkv::workload::Episode],
    final_max_new: usize,
) -> Result<(ModeStats, B)> {
    let cfg = EngineConfig {
        policy: policy.into(),
        budget,
        batch,
        max_new_tokens: final_max_new,
        ..Default::default()
    };
    let mut engine = Engine::new(backend, cfg, vocab.eos())?;
    let n = turnlists.len();
    let max_turns = turnlists.iter().map(Vec::len).max().unwrap_or(0);
    let mut finals: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut inters: Vec<Vec<Vec<u32>>> = vec![Vec::new(); n];
    let mut next_id = 0u64;
    for j in 0..max_turns {
        for (d, tl) in turnlists.iter().enumerate() {
            if j >= tl.len() {
                continue;
            }
            let last = j == tl.len() - 1;
            let mut req = Request::new(next_id, tl[j].clone(),
                                       if last { final_max_new } else { 1 })
                .with_session(format!("dlg-{d}"));
            req.tag = format!("{d}");
            next_id += 1;
            engine.submit(req).map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        for r in engine.run_to_completion()? {
            let d: usize = r.tag.parse().expect("dialogue tag");
            if j == turnlists[d].len() - 1 {
                finals[d] = r.tokens;
            } else {
                inters[d].push(r.tokens);
            }
        }
    }
    let session_summary = engine.metrics.session_summary();
    let tokens_prefilled = engine.metrics.tokens_prefilled;
    for d in 0..n {
        engine.close_session(&format!("dlg-{d}"));
    }
    let accuracy = answers
        .iter()
        .zip(&finals)
        .map(|(ep, toks)| grade(ep, toks, vocab))
        .sum::<f64>()
        / n as f64;
    Ok((
        ModeStats { accuracy, final_tokens: finals, tokens_prefilled,
                    session_summary, inter_replies: inters },
        engine.into_backend(),
    ))
}

/// Flattened baseline: one request per dialogue carrying the whole history
/// (turn prompts interleaved with the session run's sampled replies, so
/// both modes feed the model the exact same token stream).
fn run_flattened_mode<B: ModelBackend>(
    backend: B, vocab: &Vocab, policy: &str, budget: usize, batch: usize,
    turnlists: &[Vec<Vec<u32>>], replies: &[Vec<Vec<u32>>],
    answers: &[&trimkv::workload::Episode], final_max_new: usize,
) -> Result<(ModeStats, B)> {
    let cfg = EngineConfig {
        policy: policy.into(),
        budget,
        batch,
        max_new_tokens: final_max_new,
        ..Default::default()
    };
    let mut engine = Engine::new(backend, cfg, vocab.eos())?;
    let n = turnlists.len();
    for (d, tl) in turnlists.iter().enumerate() {
        let mut flat: Vec<u32> = Vec::new();
        for (j, turn) in tl.iter().enumerate() {
            flat.extend(turn);
            if let Some(reply) = replies[d].get(j) {
                flat.extend(reply);
            }
        }
        let mut req = Request::new(d as u64, flat, final_max_new);
        req.tag = format!("{d}");
        engine.submit(req).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    let mut finals: Vec<Vec<u32>> = vec![Vec::new(); n];
    for r in engine.run_to_completion()? {
        let d: usize = r.tag.parse().expect("dialogue tag");
        finals[d] = r.tokens;
    }
    let accuracy = answers
        .iter()
        .zip(&finals)
        .map(|(ep, toks)| grade(ep, toks, vocab))
        .sum::<f64>()
        / n as f64;
    let stats = ModeStats {
        accuracy,
        final_tokens: finals,
        tokens_prefilled: engine.metrics.tokens_prefilled,
        session_summary: String::new(),
        inter_replies: Vec::new(),
    };
    Ok((stats, engine.into_backend()))
}

/// What per-turn serving would cost WITHOUT sessions: every turn re-prefills
/// all prior turns plus their replies.
fn reprefill_cost(turnlists: &[Vec<Vec<u32>>], replies: &[Vec<Vec<u32>>]) -> u64 {
    let mut total = 0u64;
    for (d, tl) in turnlists.iter().enumerate() {
        let mut history = 0u64;
        for (j, turn) in tl.iter().enumerate() {
            history += turn.len() as u64;
            total += history;
            history += replies[d].get(j).map_or(0, |r| r.len() as u64);
        }
    }
    total
}

fn compare_modes<B: ModelBackend>(
    backend: B, vocab: &Vocab, policy: &str, budget: usize, batch: usize,
    n: usize, check_equivalence: bool,
) -> Result<B> {
    let suite = suites::longmem(vocab, "update", n, 99);
    let answers: Vec<&trimkv::workload::Episode> = suite.episodes.iter().collect();
    let turnlists: Vec<Vec<Vec<u32>>> = suite
        .episodes
        .iter()
        .map(|ep| split_turns(&ep.prompt, vocab))
        .collect();
    let final_max_new = 4;

    let (sess, backend) = run_session_mode(
        backend, vocab, policy, budget, batch, &turnlists, &answers,
        final_max_new)?;
    let (flat, backend) = run_flattened_mode(
        backend, vocab, policy, budget, batch, &turnlists,
        &sess.inter_replies, &answers, final_max_new)?;

    let reprefill = reprefill_cost(&turnlists, &sess.inter_replies);
    println!("{policy:>14}: session accuracy {:.3} | flattened accuracy {:.3}",
             sess.accuracy, flat.accuracy);
    println!("{:>14}  prefilled {} tok once across all turns \
              (per-turn re-prefill would cost {} tok, {:.1}x)",
             "", sess.tokens_prefilled, reprefill,
             reprefill as f64 / sess.tokens_prefilled.max(1) as f64);
    println!("{:>14}  {}", "", sess.session_summary);
    if check_equivalence {
        let same = sess.final_tokens == flat.final_tokens;
        println!("{:>14}  token-equivalence with flattened baseline: {}",
                 "", if same { "PASS" } else { "FAIL" });
        anyhow::ensure!(same, "session-served dialogue diverged from the \
                               uninterrupted baseline");
    }
    Ok(backend)
}

fn main() -> Result<()> {
    let dir = std::path::Path::new("artifacts");
    let budget = 48usize;
    if dir.join("meta.json").exists() {
        let meta = ModelMeta::load(dir)?;
        let vocab = Vocab::load(&dir.join("vocab.json"))?;
        let n = 24usize;
        let spec = meta
            .pick("decode", 8, budget + meta.chunk + 1, "mlp")
            .context("no artifact")?;
        let mut backend = Some(PjrtBackend::load(&meta, spec.b, spec.m,
                                                 "default", "mlp", true)?);
        println!("multi-session memory @ budget {budget} ({n} dialogues, \
                  8 lanes, true multi-turn serving)\n");
        for policy in ["trimkv", "streaming_llm", "snapkv"] {
            let be = compare_modes(backend.take().unwrap(), &vocab, policy,
                                   budget, 8, n, false)?;
            backend = Some(be);
        }
        println!("\nexpected shape (paper Table 8): trimkv >> snapkv ~ \
                  streaming_llm, with session == flattened accuracy");
    } else {
        println!("no artifacts — session-subsystem demo on MockBackend \
                  (12 dialogues over 4 lanes)\n");
        let vocab = Vocab::builtin();
        let backend = MockBackend::new(4, budget + 20);
        compare_modes(backend, &vocab, "trimkv", budget, 4, 12, true)?;
        println!("\nsession-served dialogues matched the uninterrupted \
                  baseline token-for-token with zero history re-prefill");
    }
    Ok(())
}
