//! Long-memory chat scenario (LongMemEval analog, paper §5.2): a
//! multi-session dialogue is streamed through a budget-bounded cache; at
//! the end the assistant is asked about facts stated sessions ago.
//! Compares TRIM-KV against StreamingLLM at the same budget.
//!
//!   make artifacts && cargo run --release --example longmem_chat

use anyhow::{Context, Result};
use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::model_meta::ModelMeta;
use trimkv::runtime::PjrtBackend;
use trimkv::scheduler::Request;
use trimkv::vocab::Vocab;
use trimkv::workload::{grade, suites};

fn main() -> Result<()> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("meta.json").exists() {
        println!("no artifacts found — run `make artifacts` first");
        return Ok(());
    }
    let meta = ModelMeta::load(dir)?;
    let vocab = Vocab::load(&dir.join("vocab.json"))?;
    let budget = 48usize;
    let n = 24usize;

    let spec = meta
        .pick("decode", 8, budget + meta.chunk + 1, "mlp")
        .context("no artifact")?;
    let mut backend = Some(PjrtBackend::load(&meta, spec.b, spec.m, "default",
                                             "mlp", true)?);
    println!("multi-session memory @ budget {budget} ({} dialogues)\n", n);
    for policy in ["trimkv", "streaming_llm", "snapkv"] {
        let cfg = EngineConfig {
            policy: policy.into(),
            budget,
            batch: 8,
            max_new_tokens: 4,
            ..Default::default()
        };
        let mut engine = Engine::new(backend.take().unwrap(), cfg, vocab.eos())?;
        let suite = suites::longmem(&vocab, "update", n, 99);
        for (i, ep) in suite.episodes.iter().enumerate() {
            engine
                .submit(Request::new(i as u64, ep.prompt.clone(), 4))
                .map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        let rs = engine.run_to_completion()?;
        let acc: f64 = rs
            .iter()
            .map(|r| grade(&suite.episodes[r.id as usize], &r.tokens, &vocab))
            .sum::<f64>()
            / rs.len() as f64;
        println!("{policy:>14}: knowledge-update accuracy {acc:.3} \
                  (evictions {})", engine.metrics.evictions);
        backend = Some(engine.into_backend());
    }
    println!("\nexpected shape (paper Table 8): trimkv >> snapkv ~ streaming_llm");
    Ok(())
}
