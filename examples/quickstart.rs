//! Quickstart: load the AOT artifacts, serve one recall episode under a
//! tight KV budget with TRIM-KV eviction, print everything.
//!
//!   make artifacts && cargo run --release --example quickstart

use anyhow::{Context, Result};
use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::model_meta::ModelMeta;
use trimkv::runtime::PjrtBackend;
use trimkv::scheduler::Request;
use trimkv::vocab::Vocab;
use trimkv::workload::{grade, Gen};

fn main() -> Result<()> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("meta.json").exists() {
        println!("no artifacts found — run `make artifacts` first");
        return Ok(());
    }
    let meta = ModelMeta::load(dir)?;
    let vocab = Vocab::load(&dir.join("vocab.json"))?;

    let cfg = EngineConfig {
        policy: "trimkv".into(),
        budget: 48,
        batch: 1,
        max_new_tokens: 8,
        ..Default::default()
    };
    let spec = meta
        .pick("decode", 1, cfg.budget + meta.chunk + 1, "mlp")
        .context("no b=1 artifact")?;
    println!("loading {} (b={} m={})", spec.file, spec.b, spec.m);
    let backend = PjrtBackend::load(&meta, spec.b, spec.m, "default", "mlp", true)?;
    let mut engine = Engine::new(backend, cfg, vocab.eos())?;

    let mut g = Gen::new(&vocab, 1234);
    let ep = g.recall(10, 4);
    println!("\nprompt ({} tokens):\n  {}", ep.prompt.len(),
             ep.prompt.iter().map(|&t| vocab.name(t)).collect::<Vec<_>>().join(" "));
    println!("expected answer: {}", vocab.name(ep.answer[0]));

    engine
        .submit(Request::new(0, ep.prompt.clone(), 8))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let rs = engine.run_to_completion()?;
    let r = &rs[0];
    println!("\ngenerated: {}",
             r.tokens.iter().map(|&t| vocab.name(t)).collect::<Vec<_>>().join(" "));
    println!("grade: {}", grade(&ep, &r.tokens, &vocab));
    println!("evictions under budget {}: {}", engine.cfg.budget,
             engine.metrics.evictions);
    println!("{}", engine.metrics.summary());
    Ok(())
}
