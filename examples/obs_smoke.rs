//! Observability smoke: drive every plane of `rust/src/obs/` end to end on
//! the deterministic mock backend, the way an operator would see it —
//!
//!   1. serve a short workload through `InProcServer` + the TCP front-end
//!      and scrape `GET /metrics` over a real socket (Prometheus text);
//!   2. snapshot the tick flight recorder and write Chrome-trace JSON
//!      (open it in Perfetto / chrome://tracing); CI uploads the file;
//!   3. print the per-(layer,head) retention-at-eviction report;
//!   4. re-run the same closed loop with the flight recorder on vs off and
//!      gate the per-step overhead (coarse bound — this is a smoke test,
//!      not a microbenchmark).
//!
//!   cargo run --release --example obs_smoke [--out obs_trace.json]
//!
//! Exits non-zero if any plane misbehaves, so CI can gate on it.

use std::io::{Read, Write as _};
use std::net::{TcpListener, TcpStream};

use anyhow::{ensure, Context, Result};
use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::runtime::MockBackend;
use trimkv::scheduler::Request;
use trimkv::server::{tcp, InProcServer};
use trimkv::util::cli::Args;
use trimkv::util::json::Json;

const BATCH: usize = 4;
const BUDGET: usize = 12;
const SLOTS: usize = 16;
const REQUESTS: u64 = 24;
const MAX_NEW: usize = 8;

fn engine(trace: bool) -> Result<Engine<MockBackend>> {
    let cfg = EngineConfig {
        budget: BUDGET,
        batch: BATCH,
        trace,
        ..Default::default()
    };
    Ok(Engine::new(MockBackend::new(BATCH, SLOTS), cfg, 2)?)
}

/// The smoke workload: prompts long enough to force evictions under the
/// budget (retention histograms need victims), varied so lanes mix decode
/// and chunked prefill in the same ticks.
fn workload() -> Vec<Request> {
    (0..REQUESTS)
        .map(|i| {
            let len = 2 + (i as usize * 7) % 28;
            let prompt: Vec<u32> =
                (0..len).map(|t| (1 + i as u32 * 13 + t as u32) % 500).collect();
            Request::new(i, prompt, MAX_NEW)
        })
        .collect()
}

/// Closed-loop run on a directly owned engine; returns mean step_us.
fn closed_loop(trace: bool) -> Result<(f64, Engine<MockBackend>)> {
    let mut eng = engine(trace)?;
    let mut pending = workload();
    let mut done = 0;
    while done < REQUESTS as usize {
        while let Some(req) = pending.first().cloned() {
            match eng.submit(req) {
                Ok(()) => {
                    pending.remove(0);
                }
                Err(_) => break, // queue full: drain a tick first
            }
        }
        eng.tick()?;
        done += eng.take_responses().len();
    }
    Ok((eng.metrics.step_us.mean(), eng))
}

fn main() -> Result<()> {
    let args = Args::spec()
        .opt("out", "obs_trace.json", "Chrome-trace output path")
        .parse_env()?;
    let out = args.get_or("out", "obs_trace.json");

    // --- 1. serving loop + live /metrics scrape over TCP ----------------
    let srv = InProcServer::spawn(engine(true)?);
    for req in workload() {
        srv.submit(req);
    }
    for _ in 0..REQUESTS {
        srv.recv_blocking().context("engine thread died mid-run")?;
    }

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let http = std::thread::spawn(move || -> Result<String> {
        let mut client = TcpStream::connect(addr)?;
        write!(client, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n")?;
        client.shutdown(std::net::Shutdown::Write)?;
        let mut raw = String::new();
        client.read_to_string(&mut raw)?;
        Ok(raw)
    });
    let (conn, _) = listener.accept()?;
    tcp::serve_connection(conn, &srv)?;
    let raw = http.join().expect("scrape thread panicked")?;
    ensure!(raw.starts_with("HTTP/1.0 200 OK\r\n"), "bad scrape: {raw}");
    let body = raw.split("\r\n\r\n").nth(1).context("no body")?;
    let expect = format!("trimkv_requests_finished_total {REQUESTS}\n");
    ensure!(body.contains(&expect), "scrape missing `{expect}`:\n{body}");
    ensure!(body.contains("trimkv_retention_evictions_total"),
            "scrape missing retention counter");
    println!("GET /metrics: {} bytes, {} series", body.len(),
             body.lines().count());
    for line in body.lines().filter(|l| {
        l.starts_with("trimkv_tokens_") || l.starts_with("trimkv_host_gap")
            || l.starts_with("trimkv_retention_evictions_total")
    }) {
        println!("  {line}");
    }

    // --- 2. flight-recorder snapshot -> Chrome-trace JSON ---------------
    let trace = srv.trace_snapshot().context("engine thread gone")?;
    let doc = Json::parse(&trace).context("trace is not valid JSON")?;
    let spans = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .context("trace has no traceEvents")?
        .len();
    ensure!(spans > 0, "flight recorder captured no spans");
    std::fs::write(&out, &trace)?;
    println!("trace: {spans} spans -> {out}");
    srv.shutdown();

    // --- 3 + 4. retention report & obs-on vs obs-off step overhead ------
    let (us_on, eng_on) = closed_loop(true)?;
    let (us_off, eng_off) = closed_loop(false)?;
    ensure!(eng_on.obs.retention.total_evictions() > 0,
            "workload produced no evictions — retention report is empty");
    ensure!(eng_off.obs.journal.is_empty(),
            "journal recorded events with trace disabled");
    // the default loop is pipelined: runnable ticks always step the
    // backend, and the overlap windows it opens must be accounted
    ensure!(eng_on.obs.journal.host_gap_ticks == 0,
            "pipelined run left {} host-gap ticks",
            eng_on.obs.journal.host_gap_ticks);
    ensure!(eng_on.obs.journal.overlap_ns > 0,
            "pipelined run recorded no overlap time");
    println!("\n{}", eng_on.retention_report());
    println!("step_us mean: obs-on {us_on:.1}, obs-off {us_off:.1}");
    // coarse gate: recording a handful of ring-buffer events per tick must
    // stay in the noise next to a mock graph execution
    ensure!(us_on <= us_off * 2.0 + 200.0,
            "flight recorder overhead out of bounds: on={us_on:.1}us \
             off={us_off:.1}us");
    println!("obs smoke: ALL OK");
    Ok(())
}
