//! End-to-end serving driver (the repo's headline validation run): a mixed
//! task workload is batch-served through the full stack — rust coordinator
//! -> continuous batcher -> TRIM-KV cache manager -> AOT decode graph on
//! PJRT — and we report accuracy, throughput and latency percentiles.
//! Results are recorded in EXPERIMENTS.md.
//!
//!   make artifacts && cargo run --release --example batch_serving
//!   [--policy trimkv] [--budget 96] [--requests 48]

use anyhow::{Context, Result};
use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::model_meta::ModelMeta;
use trimkv::runtime::PjrtBackend;
use trimkv::scheduler::Request;
use trimkv::server::InProcServer;
use trimkv::util::cli::Args;
use trimkv::util::stats::Percentiles;
use trimkv::vocab::Vocab;
use trimkv::workload::{grade, suites};

fn main() -> Result<()> {
    let args = Args::spec()
        .opt("policy", "trimkv", "eviction policy")
        .opt("budget", "96", "kv budget per head")
        .opt("requests", "48", "number of requests")
        .opt("batch", "8", "batch lanes")
        .parse_env()?;
    let dir = std::path::Path::new("artifacts");
    if !dir.join("meta.json").exists() {
        println!("no artifacts found — run `make artifacts` first");
        return Ok(());
    }
    let meta = ModelMeta::load(dir)?;
    let vocab = Vocab::load(&dir.join("vocab.json"))?;
    let budget = args.usize("budget")?;
    let batch = args.usize("batch")?;
    let policy = args.get_or("policy", "trimkv");
    let n = args.usize("requests")?;

    let cfg = EngineConfig {
        policy: policy.clone(),
        budget,
        batch,
        ..Default::default()
    };
    let spec = meta
        .pick("decode", batch, budget + meta.chunk + 1, "mlp")
        .context("no artifact for this batch/budget")?;
    println!("loading {} (b={} m={}), policy {policy}, budget {budget}",
             spec.file, spec.b, spec.m);
    let backend = PjrtBackend::load(&meta, spec.b, spec.m, "default", "mlp", true)?;
    let engine = Engine::new(backend, cfg, vocab.eos())?;
    let srv = InProcServer::spawn(engine);

    // mixed workload: one episode per paper suite family
    let mut episodes = Vec::new();
    episodes.extend(suites::math(&vocab, "gsm8k", n / 3, 1).episodes);
    episodes.extend(suites::longmem(&vocab, "single", n / 3, 2).episodes);
    episodes.extend(suites::scbench(&vocab, "manyshot", n - 2 * (n / 3), 3).episodes);

    let t0 = std::time::Instant::now();
    for (i, ep) in episodes.iter().enumerate() {
        let mut req = Request::new(i as u64, ep.prompt.clone(), 24);
        req.tag = ep.task.clone();
        srv.submit(req);
    }
    let responses = srv.shutdown();
    let wall = t0.elapsed().as_secs_f64();

    let mut score = 0.0;
    let mut ttft = Percentiles::default();
    let mut e2e = Percentiles::default();
    let mut decoded = 0usize;
    for r in &responses {
        score += grade(&episodes[r.id as usize], &r.tokens, &vocab);
        ttft.push(r.ttft_us / 1e3);
        e2e.push(r.e2e_us / 1e3);
        decoded += r.tokens.len();
    }
    println!("\n=== batch serving report ===");
    println!("requests           {}", responses.len());
    println!("mean accuracy      {:.3}", score / responses.len() as f64);
    println!("wall time          {wall:.2} s");
    println!("decode throughput  {:.1} tok/s", decoded as f64 / wall);
    println!("request rate       {:.2} req/s", responses.len() as f64 / wall);
    println!("ttft p50/p95       {:.1} / {:.1} ms", ttft.pct(50.0), ttft.pct(95.0));
    println!("e2e  p50/p95       {:.1} / {:.1} ms", e2e.pct(50.0), e2e.pct(95.0));
    Ok(())
}
