//! Chunked-prefill demo (paper §B.3 / LocRet setting): a long prompt is
//! prefetched chunk-by-chunk, compressing the cache to the budget after
//! every chunk, then generation proceeds from the compressed state.
//!
//!   make artifacts && cargo run --release --example chunked_prefill

use anyhow::{Context, Result};
use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::model_meta::ModelMeta;
use trimkv::runtime::PjrtBackend;
use trimkv::scheduler::Request;
use trimkv::vocab::Vocab;
use trimkv::workload::{grade, Gen};

fn main() -> Result<()> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("meta.json").exists() {
        println!("no artifacts found — run `make artifacts` first");
        return Ok(());
    }
    let meta = ModelMeta::load(dir)?;
    let vocab = Vocab::load(&dir.join("vocab.json"))?;
    let budget = 64usize;

    let spec = meta
        .pick("decode", 1, budget + meta.chunk + 1, "mlp")
        .context("no b=1 artifact")?;
    let mut backend = Some(PjrtBackend::load(&meta, spec.b, spec.m, "default",
                                             "mlp", true)?);
    let mut g = Gen::new(&vocab, 2718);
    let ep = g.niah(260); // needle buried in a ~260-token haystack
    println!("prompt: {} tokens, needle answer {}; budget {budget}, \
              chunk {}", ep.prompt.len(), vocab.name(ep.answer[0]), meta.chunk);

    for chunked in [true, false] {
        let cfg = EngineConfig {
            policy: "trimkv".into(),
            budget,
            batch: 1,
            max_new_tokens: 4,
            chunked_prefill: chunked,
            ..Default::default()
        };
        let mut engine = Engine::new(backend.take().unwrap(), cfg, vocab.eos())?;
        let t0 = std::time::Instant::now();
        engine
            .submit(Request::new(0, ep.prompt.clone(), 4))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let rs = engine.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "chunked_prefill={chunked:5}: prefill {} tok in {} chunks + {} \
             decode steps | ttft {:.1} ms | wall {:.2} s | grade {} | evictions {}",
            engine.metrics.tokens_prefilled,
            engine.metrics.prefill_chunks,
            engine.metrics.decode_steps,
            rs[0].ttft_us / 1e3,
            wall,
            grade(&ep, &rs[0].tokens, &vocab),
            engine.metrics.evictions,
        );
        backend = Some(engine.into_backend());
    }
    println!("\nchunked prefill cuts time-to-first-token by processing the \
              prompt {}x fewer graph invocations", meta.chunk);
    Ok(())
}
