//! Offline stand-in for the `anyhow` crate (registry access is unavailable
//! in the build environment — see DESIGN.md §2 for the no-deps policy).
//!
//! Implements the API subset this workspace uses: `Error`, `Result`,
//! `Context` for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros.  Errors are flattened to a context chain of strings; no backtrace
//! capture, no downcasting.

use std::fmt;

/// Dynamic error: a message plus the chain of contexts wrapped around it,
/// outermost first (matching anyhow's Display of `{context}: {cause}`).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    pub fn context(mut self, ctx: impl fmt::Display) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The outermost message (first context, or the root cause).
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints through Debug
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // include source chain like anyhow's {:#}
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment for fallible values, mirroring anyhow::Context.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_render_outermost_first() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading config: gone");
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u8>.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
        let e = anyhow!("plain {}", "msg");
        assert_eq!(e.root_message(), "plain msg");
    }
}
