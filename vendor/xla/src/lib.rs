//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The real PJRT runtime (XLA C API + compiled HLO execution) is not
//! vendorable in this environment, so this crate provides the exact API
//! surface `trimkv::runtime` compiles against.  Every entry point that would
//! touch a device returns `Err(XlaError::Unavailable)` at runtime; the
//! engine's artifact checks mean these paths are only reached when a user
//! explicitly points the binary at exported artifacts.  Swap this crate for
//! the real bindings (same module paths) to run on hardware.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub enum XlaError {
    Unavailable,
    Io(String),
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            XlaError::Unavailable => write!(
                f,
                "PJRT runtime unavailable: this build uses the vendored xla \
                 stub; link the real xla-rs bindings to execute artifacts"
            ),
            XlaError::Io(m) => write!(f, "xla stub io error: {m}"),
        }
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Host element types accepted by `buffer_from_host_buffer`.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

pub struct PjRtDevice;

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::Unavailable)
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(XlaError::Unavailable)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::Unavailable)
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::Unavailable)
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::Unavailable)
    }
}

pub struct Literal;

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(XlaError::Unavailable)
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        // surface a useful error before the (unreachable) compile step
        if !path.exists() {
            return Err(XlaError::Io(format!("no such HLO file: {path:?}")));
        }
        Err(XlaError::Unavailable)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("unavailable"));
    }
}
